"""repro.serve correctness: continuous batching must be invisible.

The contract that makes the slot-pool machinery trustable is exact
token equivalence: a request served by the continuous-batching engine —
joining mid-flight, sharing decode ticks with strangers, surviving
chunked prefill and masked dead lanes — must emit the identical greedy
token stream as a lone offline run of the same model. Checked across an
attention family and a recurrent family (the two cache disciplines).

Plus: slot-pool allocate/free/reuse/defrag/reset invariants, scheduler
determinism, and the hedged router's order-statistics pricing
(brute-force ``expected_kth`` match, loser cancellation freeing slots,
EWMA straggler demotion).
"""

import gc
import weakref

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.delay_models import GeneralizedDelayModel, SimplifiedDelayModel
from repro.core.order_stats import expected_kth
from repro.models import build_model
from repro.models.layers import ParamSpec, is_paged_spec
from repro.serve import (
    BlockManager,
    HedgedRouter,
    ReplicaSet,
    Scheduler,
    ServeEngine,
    SlotPool,
    generate_offline,
    run_static,
)

RNG = jax.random.PRNGKey(0)
MAX_LEN = 64


def _model(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    return model, model.init(RNG)


def _workload(vocab, n=6, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        p = int(rng.integers(3, 20))
        m = int(rng.integers(1, 12))
        prompt = rng.integers(0, vocab, size=p).astype(np.int32)
        reqs.append((prompt, m, i * 0.004))
    return reqs


# ---------------------------------------------------------------------------
# Token equivalence: continuous batching == offline decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-135m", "xlstm-125m"])
def test_continuous_batching_matches_offline(arch):
    """Staggered arrivals, mixed lengths, chunked prefill, 3 slots for 6
    requests — every request's greedy tokens must be identical to a
    per-request offline decode (attention + recurrent cache families)."""
    model, params = _model(arch)
    reqs = _workload(model.cfg.vocab_size)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=MAX_LEN,
        scheduler=Scheduler(3, prefill_chunk=8, decode_per_prefill=2),
    )
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    results = eng.run()
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, m, MAX_LEN)
        assert results[rid].tokens == ref, f"{arch} rid={rid} diverged"
        assert results[rid].t_done is not None


def test_static_baseline_matches_offline():
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=5, seed=3)
    results, stats = run_static(model, params, reqs, n_slots=2, max_len=MAX_LEN)
    for rid, (p, m, _) in zip(sorted(results), reqs):
        assert results[rid].tokens == generate_offline(model, params, p, m, MAX_LEN)
    assert stats.generated_tokens == sum(m for _, m, _ in reqs)


def test_slots_reused_across_requests():
    """More requests than slots forces mid-flight reuse of freed slots."""
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=7, seed=5)
    eng = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN)
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    results = eng.run()
    assert eng.pool.n_active == 0
    for rid, (p, m, _) in zip(rids, reqs):
        assert results[rid].tokens == generate_offline(model, params, p, m, MAX_LEN)


def test_engine_event_log_is_deterministic():
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=6, seed=1)

    def go():
        eng = ServeEngine(model, params, n_slots=3, max_len=MAX_LEN)
        for p, m, a in reqs:
            eng.submit(p, m, arrival=a)
        eng.run()
        return eng.events

    assert go() == go()


def test_prefill_bucket_capped_at_max_len():
    """Regression: the pad bucket must never exceed the slot capacity past
    the chunk start — an oversized dynamic_update_slice either crashes or
    gets its start clamped by XLA, silently overwriting valid cache rows."""
    model, params = _model("smollm-135m")
    rng = np.random.default_rng(11)
    # (a) bucket(24) = 32 > max_len = 29: would crash unclamped.
    prompt = rng.integers(0, model.cfg.vocab_size, size=24).astype(np.int32)
    eng = ServeEngine(model, params, n_slots=1, max_len=29)
    rid = eng.submit(prompt, 4)
    assert eng.run()[rid].tokens == generate_offline(model, params, prompt, 4, 29)
    # (b) chunked: last chunk start=30, bucket 16 would clamp to start 24
    # and corrupt rows 24-29 — tokens must still match offline exactly.
    prompt = rng.integers(0, model.cfg.vocab_size, size=34).astype(np.int32)
    eng = ServeEngine(
        model, params, n_slots=1, max_len=40,
        scheduler=Scheduler(1, prefill_chunk=5),
    )
    rid = eng.submit(prompt, 5)
    assert eng.run()[rid].tokens == generate_offline(model, params, prompt, 5, 40)


def test_engine_defrag_mid_flight_keeps_equivalence():
    """Defragging while requests are generating must remap the engine's
    per-slot decode state along with the pool rows."""
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=5, seed=9)
    eng = ServeEngine(model, params, n_slots=3, max_len=MAX_LEN)
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    defragged = 0
    while eng.step() != "done":
        # Defrag whenever the pool fragments (a freed slot below a live one).
        act = eng.pool.active
        if act.any() and not act[: eng.pool.n_active].all():
            assert eng.defrag()
            defragged += 1
    assert defragged > 0, "workload never fragmented the pool; weak test"
    results = dict(eng._requests)
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, m, MAX_LEN)
        assert results[rid].tokens == ref, f"rid={rid} diverged after defrag"


# ---------------------------------------------------------------------------
# Slot pool invariants
# ---------------------------------------------------------------------------

def test_slot_pool_allocate_free_reuse():
    model, _ = _model("smollm-135m")
    pool = SlotPool(model, n_slots=3, max_len=8)
    slots = [pool.allocate(owner=i) for i in range(3)]
    assert slots == [0, 1, 2] and pool.n_free == 0
    assert pool.allocate() is None          # full
    pool.free(1)
    assert pool.allocate(owner=9) == 1      # lowest free slot reused
    with pytest.raises(ValueError):
        pool.free(1)
        pool.free(1)                        # double free rejected


def test_slot_pool_defrag_compacts_and_preserves():
    model, _ = _model("smollm-135m")
    pool = SlotPool(model, n_slots=4, max_len=8)
    for i in range(4):
        pool.allocate(owner=i)
    # Stamp recognizable content via per-slot writes.
    for s in range(4):
        one = jax.tree.map(
            lambda spec: np.full([1 if a == "act_batch" else d
                                  for a, d in zip(spec.axes, spec.shape)],
                                 float(s + 1), np.float32),
            pool.specs, is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        pool.write_slot(s, one, position=s + 1)
    pool.free(0)
    pool.free(2)
    moves = pool.defrag()
    # Active slots 1,3 compact to 0,1 with contents and positions intact.
    assert moves == {1: 0, 3: 1}
    assert pool.active.tolist() == [True, True, False, False]
    assert pool.owner[:2] == [1, 3]
    assert pool.positions[:2].tolist() == [2, 4]
    leaf = jax.tree.leaves(pool.caches)[0]
    ax = jax.tree.leaves(
        pool.specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )[0].axes.index("act_batch")
    got = np.moveaxis(np.asarray(leaf, np.float32), ax, 0).reshape(4, -1)[:, 0]
    assert got[:2].tolist() == [2.0, 4.0]


def test_slot_pool_reset_restores_spec_init():
    """Reset must restore spec-defined fills — notably ONES for the sLSTM
    normalizer state, not a blanket zero. (The 2-layer reduced xlstm has
    no sLSTM block, so force one in — the pool never needs params.)"""
    import dataclasses

    cfg = get_config("xlstm-125m").reduced()
    cfg = dataclasses.replace(
        cfg, xlstm=dataclasses.replace(cfg.xlstm, slstm_every=2)
    )
    model = build_model(cfg)
    pool = SlotPool(model, n_slots=2, max_len=8)
    # Scribble over both slots.
    junk = jax.tree.map(
        lambda spec: np.full(spec.shape, 7.0, np.float32),
        pool.specs, is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    pool.caches = jax.tree.map(lambda c, j: j.astype(np.asarray(c).dtype),
                               pool.caches, junk)
    pool.reset_slot(0)
    specs = jax.tree.leaves(pool.specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    leaves = jax.tree.leaves(pool.caches)
    assert any(s.init == "ones" for s in specs), "xlstm must carry a ones-init state"
    for spec, leaf in zip(specs, leaves):
        ax = spec.axes.index("act_batch")
        arr = np.moveaxis(np.asarray(leaf, np.float32), ax, 0)
        want = 1.0 if spec.init == "ones" else 0.0
        assert np.all(arr[0] == want), f"slot 0 of {spec} not reset to {want}"
        assert np.all(arr[1] == 7.0), "reset must not touch other slots"


# ---------------------------------------------------------------------------
# Paged KV: block-table engine must be invisible too
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "arch", ["smollm-135m", "deepseek-v3", "xlstm-125m", "zamba2"]
)
def test_paged_engine_matches_offline(arch):
    """The byte-identity contract under paging, for all four cache
    disciplines (GQA KV, MLA latent, pure recurrent, hybrid): chunked
    prefill, slot reuse, AND arena pressure (10 blocks < the 18 a full
    pool would reserve, so admissions queue on block budget) must leave
    every request's greedy tokens identical to contiguous offline
    decode."""
    model, params = _model(arch)
    reqs = _workload(model.cfg.vocab_size, n=5)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=48,
        scheduler=Scheduler(3, prefill_chunk=8, decode_per_prefill=2),
        block_size=8, arena_blocks=10,
    )
    rids = [eng.submit(p, min(m, 24), arrival=a) for p, m, a in reqs]
    results = eng.run()
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, min(m, 24), 48)
        assert results[rid].tokens == ref, f"{arch} rid={rid} diverged (paged)"
    if eng.pool.manager is not None:
        eng.pool.manager.check()
        assert eng.pool.manager.n_free_blocks == eng.pool.manager.num_blocks


def test_paged_engine_defrag_mid_flight():
    """Defrag under paging permutes host block tables only (device
    gather happens just for contiguous leaves — none here) and must keep
    token equivalence."""
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=5, seed=9)
    eng = ServeEngine(model, params, n_slots=3, max_len=MAX_LEN, block_size=16)
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    defragged = 0
    while eng.step() != "done":
        act = eng.pool.active
        if act.any() and not act[: eng.pool.n_active].all():
            if eng.defrag():
                defragged += 1
            eng.pool.manager.check()
    assert defragged > 0, "workload never fragmented the pool; weak test"
    for rid, (p, m, _) in zip(rids, reqs):
        assert eng._requests[rid].tokens == generate_offline(
            model, params, p, m, MAX_LEN
        ), f"rid={rid} diverged after paged defrag"


def test_paged_pool_defrag_is_device_noop_for_attention():
    """Pure-attention pools have only paged leaves: defrag must not
    touch (or copy) the arenas at all — block tables permute host-side."""
    model, _ = _model("smollm-135m")
    pool = SlotPool(model, n_slots=4, max_len=32, block_size=16)
    assert all(is_paged_spec(s) for s in pool._spec_leaves)
    for i in range(4):
        assert pool.allocate(owner=i, n_tokens=20) is not None
        pool.ensure_rows(i, 20)   # physically place the slot's 2 blocks
    tables_before = pool.manager.tables.copy()
    leaves_before = jax.tree.leaves(pool.caches)
    pool.free(0)
    pool.free(2)
    moves = pool.defrag()
    assert moves == {1: 0, 3: 1}
    # Device arenas are the very same buffers (no gather ran).
    for a, b in zip(jax.tree.leaves(pool.caches), leaves_before):
        assert a is b
    # Block tables moved with their slots.
    assert (pool.manager.tables[0] == tables_before[1]).all()
    assert (pool.manager.tables[1] == tables_before[3]).all()
    pool.manager.check()


def test_paged_pool_commit_append_free_lifecycle():
    model, _ = _model("smollm-135m")
    pool = SlotPool(model, n_slots=2, max_len=32, block_size=8, arena_blocks=6)
    mgr = pool.manager
    s0 = pool.allocate(owner=0, n_tokens=17)     # commits 3 blocks, owns 0
    assert mgr.n_committed_blocks == 3 and mgr.n_used_blocks == 0
    pool.ensure_rows(s0, 9)                      # rows -> physical blocks
    assert mgr.n_used_blocks == 2
    pool.ensure_rows(s0, 9)                      # idempotent
    assert mgr.n_used_blocks == 2
    # Admission is bounded by COMMITTED budgets, not physical blocks.
    assert pool.can_admit(24) and not pool.can_admit(25)
    assert pool.allocate(owner=1, n_tokens=25) is None
    # Growing past the committed budget is a programming error.
    with pytest.raises(ValueError, match="budget"):
        pool.ensure_rows(s0, 25)
    pool.free(s0)
    assert mgr.n_free_blocks == 6 and mgr.n_committed_blocks == 0
    assert pool.can_admit(32)                    # full slot now fits
    assert mgr.used_high_water == 2              # live-token high-water
    mgr.check()


def test_paged_engine_rejects_oversized_request():
    model, params = _model("smollm-135m")
    eng = ServeEngine(model, params, n_slots=2, max_len=48,
                      block_size=8, arena_blocks=4)
    with pytest.raises(ValueError, match="arena"):
        eng.submit(np.arange(30, dtype=np.int32), 10)   # 5 blocks > 4


# ---------------------------------------------------------------------------
# BlockManager invariants
# ---------------------------------------------------------------------------

def test_block_manager_invariants():
    mgr = BlockManager(n_slots=3, n_rows=64, block_size=16, num_blocks=8)
    assert mgr.table_width == 4
    mgr.commit(0, 33)                  # budget 3 blocks
    mgr.commit(1, 64)                  # budget 4 blocks
    mgr.check()
    assert mgr.n_committed_blocks == 7 and mgr.n_used_blocks == 0
    mgr.append(0, 17)                  # 2 physical blocks
    mgr.append(1, 64)                  # 4 physical blocks
    assert mgr.n_used_blocks == 6 and mgr.used_high_water == 6
    mgr.append(0, 30)                  # still 2 blocks: no growth
    assert mgr.n_used_blocks == 6
    mgr.append(0, 33)                  # grows to 3 (its full budget)
    assert mgr.n_used_blocks == 7
    mgr.check()
    # Commitment and capacity bounds.
    assert not mgr.can_commit(17)      # 2 more blocks > 8 - 7 committed
    assert mgr.can_commit(16)
    with pytest.raises(ValueError, match="over-committed"):
        mgr.commit(2, 33)
    with pytest.raises(ValueError, match="table width"):
        mgr.commit(2, 65)              # > slot capacity regardless of free
    with pytest.raises(ValueError, match="budget"):
        mgr.append(0, 49)              # past its own commitment
    # Free returns blocks AND budget instantly; tables go back to NULL.
    mgr.free(1)
    assert mgr.n_free_blocks == 5 and mgr.n_committed_blocks == 3
    assert (mgr.tables[1] == 0).all()
    mgr.check()
    mgr.free(0)
    assert mgr.n_free_blocks == 8
    assert mgr.used_high_water == 7    # high-water survives frees
    mgr.check()


def test_block_manager_never_hands_out_a_block_twice():
    mgr = BlockManager(n_slots=4, n_rows=32, block_size=8, num_blocks=12)
    rng = np.random.default_rng(0)
    budget = [0] * 4
    for _ in range(300):
        slot = int(rng.integers(4))
        p = rng.random()
        if budget[slot] and p < 0.3:
            mgr.free(slot)
            budget[slot] = 0
        elif budget[slot]:
            mgr.append(slot, int(rng.integers(1, budget[slot] + 1)))
        else:
            want = int(rng.integers(1, 33))
            if mgr.can_commit(want):
                mgr.commit(slot, want)
                budget[slot] = want
        mgr.check()   # asserts disjoint ownership + free-list integrity


def test_block_size_must_divide_rows():
    model, _ = _model("smollm-135m")
    with pytest.raises(ValueError, match="divide"):
        SlotPool(model, n_slots=2, max_len=32, block_size=24)


# ---------------------------------------------------------------------------
# Model lifetime: pool/engine jit caches must not pin dropped models
# ---------------------------------------------------------------------------

def test_dropped_model_pool_ops_collectable():
    """Regression: ``_pool_ops``/``_engine_steps`` used to live in a
    module-level lru_cache keyed on the model, pinning every model ever
    served (and its jit traces) for the process lifetime. The memo now
    lives on the model instance, so dropping the model frees it."""
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    pool = SlotPool(model, n_slots=2, max_len=16)
    assert any(k.startswith("_memo_") for k in model.__dict__), (
        "pool ops memo should live on the model instance"
    )
    ref = weakref.ref(model)
    del pool, model
    gc.collect()
    assert ref() is None, "dropped model is still pinned by the ops cache"


# ---------------------------------------------------------------------------
# Hedged router
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delay_model", [
    SimplifiedDelayModel(lambda_y=2.0, x=0.05),
    GeneralizedDelayModel(lambda_x=4.0, lambda_y=2.0, x=0.02),
])
@pytest.mark.parametrize("quorum,c", [(1, 0.08), (2, 0.05)])
def test_hedge_choice_matches_bruteforce(delay_model, quorum, c):
    n_rep = 8
    router = HedgedRouter(delay_model, n_rep, quorum=quorum, cost_per_replica=c)
    plan = router.choose_hedge()
    brute = min(
        range(quorum, n_rep + 1),
        key=lambda n: expected_kth(delay_model, n, min(quorum, n), 1.0) + c * n,
    )
    assert plan.n_h == brute
    assert plan.k == min(quorum, plan.n_h)
    assert len(plan.replicas) == plan.n_h
    assert plan.expected_cost == pytest.approx(
        expected_kth(delay_model, plan.n_h, plan.k, 1.0) + c * plan.n_h
    )


def test_hedge_cancellation_frees_slots():
    dm = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    router = HedgedRouter(dm, 6, quorum=1, cost_per_replica=0.08)
    rs = ReplicaSet(dm, [1.0] * 6, seed=2)
    out = router.dispatch(rs, auto_complete=False)
    assert out.plan.n_h > 1, "this pricing must actually hedge"
    assert router.inflight.sum() == out.plan.n_h
    # A concurrent hedge must avoid the busy replicas.
    out2 = router.dispatch(rs, auto_complete=False)
    assert set(out2.plan.replicas).isdisjoint(out.plan.replicas)
    # Completion releases the winner AND every cancelled loser.
    assert len(out.completed) == out.plan.k
    assert len(out.cancelled) == out.plan.n_h - out.plan.k
    router.complete(out)
    router.complete(out2)
    assert router.inflight.sum() == 0
    assert sorted(router.available()) == list(range(6))


def test_router_demotes_persistent_straggler():
    dm = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    router = HedgedRouter(dm, 5, quorum=1, cost_per_replica=0.05)
    rs = ReplicaSet(dm, [1.0, 1.0, 1.0, 1.0, 8.0], seed=3)
    for _ in range(300):
        router.dispatch(rs)
    plan = router.choose_hedge()
    assert 4 not in plan.replicas, "EWMA-slow replica must stop being chosen"


def test_router_respects_quorum_capacity():
    dm = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    router = HedgedRouter(dm, 3, quorum=2, cost_per_replica=0.0, n_max=3)
    rs = ReplicaSet(dm, [1.0] * 3, seed=4)
    out = router.dispatch(rs, auto_complete=False)
    assert out is not None
    # Fewer free replicas than the quorum -> no feasible hedge.
    assert router.dispatch(rs, auto_complete=False) is None
    router.complete(out)
    assert router.dispatch(rs) is not None
