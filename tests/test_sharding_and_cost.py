"""Sharding rules unit tests + loop-aware HLO cost analysis validation +
a multi-device (forced host platform) end-to-end sharded train step run
in a subprocess (so the device-count flag cannot leak into other tests).
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.models.layers import ParamSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# logical_to_pspec
# ---------------------------------------------------------------------------

def _mesh_stub(shape_map):
    class M:
        shape = shape_map
    return M()


def test_pspec_divisibility_fallback():
    from repro.dist.sharding import DEFAULT_RULES, logical_to_pspec

    mesh = _mesh_stub({"data": 16, "model": 16})
    # 9 heads not divisible by 16 -> replicated; ffn 1536/16 ok.
    p = logical_to_pspec(("embed", "heads", "head_dim"), (576, 9, 64), mesh,
                         DEFAULT_RULES)
    assert p[0] == "data" and (len(p) < 2 or p[1] is None)
    p2 = logical_to_pspec(("embed", "ffn"), (576, 1536), mesh, DEFAULT_RULES)
    assert tuple(p2) == ("data", "model")


def test_pspec_missing_axis_dropped_from_tuple():
    from repro.dist.sharding import DEFAULT_RULES, logical_to_pspec

    single_pod = _mesh_stub({"data": 16, "model": 16})
    # act_batch = (pod, data): pod absent -> just data.
    p = logical_to_pspec(("act_batch", None), (128, 32768), single_pod,
                         DEFAULT_RULES)
    assert p[0] == "data"


def test_pspec_no_mesh_axis_reuse():
    from repro.dist.sharding import DEFAULT_RULES, logical_to_pspec

    mesh = _mesh_stub({"data": 4, "model": 4})
    # vocab and heads both map to model: only the first dim takes it.
    p = logical_to_pspec(("vocab", "heads"), (512, 8), mesh, DEFAULT_RULES)
    assert p[0] == "model"
    assert len(p) < 2 or p[1] is None


def test_pspec_partial_tuple_divisibility():
    from repro.dist.sharding import DEFAULT_RULES, logical_to_pspec

    mesh = _mesh_stub({"pod": 2, "data": 16, "model": 16})
    # batch 8: not divisible by 32 but divisible by pod (2) after dropping
    # the trailing axis.
    p = logical_to_pspec(("act_batch",), (8,), mesh, DEFAULT_RULES)
    assert tuple(p) == ("pod",)


# ---------------------------------------------------------------------------
# Loop-aware HLO cost pass (vs hand-computed ground truth)
# ---------------------------------------------------------------------------

def test_hlo_cost_counts_scan_trip_counts():
    import jax
    import jax.numpy as jnp
    from repro.analysis.hlo_cost import analyze_hlo

    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, jnp.arange(7))
        return h.sum()

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    cost = analyze_hlo(compiled.as_text())
    expected = 7 * 2 * 8 * 64 * 64  # trips * 2MNK
    assert cost.flops == pytest.approx(expected, rel=0.05)
    assert cost.unknown_trip_counts == 0

    xla = compiled.cost_analysis()
    if isinstance(xla, (list, tuple)):  # older jax returns [dict]
        xla = xla[0]
    # Sanity: XLA's own count misses the loop multiplier (that's WHY the
    # custom pass exists); if XLA ever fixes this, drop the custom pass.
    assert xla["flops"] < cost.flops


def test_hlo_cost_nested_loops():
    import jax
    import jax.numpy as jnp
    from repro.analysis.hlo_cost import analyze_hlo

    def f(w, x):
        def outer(h, _):
            def inner(g, _):
                return jnp.tanh(g @ w), ()
            g, _ = jax.lax.scan(inner, h, jnp.arange(3))
            return g, ()
        h, _ = jax.lax.scan(outer, x, jnp.arange(5))
        return h.sum()

    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    cost = analyze_hlo(compiled.as_text())
    expected = 5 * 3 * 2 * 4 * 32 * 32
    assert cost.flops == pytest.approx(expected, rel=0.05)


# ---------------------------------------------------------------------------
# Multi-device sharded step (subprocess: needs forced device count)
# ---------------------------------------------------------------------------

SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.dist.sharding import DEFAULT_RULES, activation_sharding
    from repro.launch.specs import abstract_state, train_input_specs
    from repro.configs.shapes import ShapeSpec
    from repro.models.model import Model
    from repro.optim.optimizers import get_optimizer
    from repro.runtime.steps import make_train_step
    from repro.models.layers import init_from_specs
    from repro.dist.sharding import make_sharding_fn

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("smollm-135m").reduced(vocab_size=512, max_seq_len=64)
    model = Model(cfg)
    opt = get_optimizer("adamw")
    with jax.set_mesh(mesh), activation_sharding(mesh):
        fn = make_sharding_fn(mesh, DEFAULT_RULES)
        params = jax.jit(
            model.init, out_shardings=jax.tree.map(
                lambda s: fn(s), model.param_specs(),
                is_leaf=lambda x: hasattr(x, "axes"))
        )(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        step = jax.jit(make_train_step(model, opt))
        B, S = 8, 32
        rng = jax.random.PRNGKey(1)
        batch = {
            "inputs": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
            "worker_mask": jnp.array([1.0, 1.0, 0.0, 1.0]),
            "lr": jnp.float32(1e-3),
        }
        losses = []
        for _ in range(5):
            params, opt_state, metrics = step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
    print(json.dumps({
        "losses": losses,
        "n_devices": jax.device_count(),
        "contributors": float(metrics["contributors"]),
    }))
    """
)


def test_sharded_train_step_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["n_devices"] == 8
    assert data["contributors"] == 3.0
    losses = data["losses"]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
