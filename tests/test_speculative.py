"""Speculative decoding correctness: draft-then-verify must be invisible.

The contract mirrors the rest of repro.serve: speculation is purely a
throughput bet, so the greedy token stream of a draft-attached engine —
joining mid-flight, rolling back rejected drafts, surviving defrag and
arena pressure — must be byte-identical to a lone offline decode, for
attention and recurrent cache disciplines, with a good draft, a useless
draft, and a perfect draft. Plus: the verify step's family-specific
commit semantics, the gamma controller's pricing (incl. the
``expected_kth`` hedged composition), and the scheduler's verify-debt
accounting (speculation must not starve admissions under arena
pressure).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.delay_models import SimplifiedDelayModel
from repro.core.order_stats import expected_kth
from repro.models import build_model
from repro.serve import (
    CostModel,
    Scheduler,
    ServeEngine,
    SpecController,
    generate_offline,
    hedged_round_cost,
)
from repro.serve.speculative import expected_round_tokens

RNG = jax.random.PRNGKey(0)
MAX_LEN = 64


def _model(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    return model, model.init(RNG)


def _perturb(params, scale, seed=7):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(
        treedef,
        [l + scale * jax.random.normal(k, l.shape, l.dtype)
         for l, k in zip(leaves, keys)],
    )


def _workload(vocab, n=6, seed=0, min_new=1, max_new=12):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        p = int(rng.integers(3, 20))
        m = int(rng.integers(min_new, max_new))
        prompt = rng.integers(0, vocab, size=p).astype(np.int32)
        reqs.append((prompt, m, i * 0.004))
    return reqs


def _assert_offline_identical(eng, model, params, rids, reqs, max_len=MAX_LEN):
    results = dict(eng._requests)
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, m, max_len)
        assert results[rid].tokens == ref, f"rid={rid} diverged"


# ---------------------------------------------------------------------------
# Byte-identity: speculative engine == offline decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-135m", "xlstm-125m"])
@pytest.mark.parametrize("noise", [3e-4, 2e-2])  # useful draft / useless draft
def test_speculative_matches_offline(arch, noise):
    """Attention + recurrent targets, good and near-useless drafts: the
    greedy stream must be byte-identical to offline decode either way —
    draft quality may only move throughput."""
    model, params = _model(arch)
    reqs = _workload(model.cfg.vocab_size)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=MAX_LEN,
        scheduler=Scheduler(3, prefill_chunk=8, decode_per_prefill=2),
        draft_model=build_model(model.cfg), draft_params=_perturb(params, noise),
        gamma_max=4,
    )
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    eng.run()
    _assert_offline_identical(eng, model, params, rids, reqs)
    assert eng.stats.spec_rounds > 0


@pytest.mark.parametrize("arch", ["smollm-135m", "xlstm-125m"])
def test_speculative_paged_matches_offline(arch):
    """Paged target pool under arena pressure: verify writes must stay
    inside committed block budgets (ragged draft lengths as data), and
    rollback must be block-table-aware."""
    model, params = _model(arch)
    reqs = _workload(model.cfg.vocab_size, n=5)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=48,
        scheduler=Scheduler(3, prefill_chunk=8, decode_per_prefill=2),
        block_size=8, arena_blocks=10,
        draft_model=build_model(model.cfg), draft_params=_perturb(params, 3e-4),
        gamma_max=4,
    )
    rids = [eng.submit(p, min(m, 24), arrival=a) for p, m, a in reqs]
    eng.run()
    results = dict(eng._requests)
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, min(m, 24), 48)
        assert results[rid].tokens == ref, f"rid={rid} diverged (paged spec)"
    eng.pool.manager.check()
    assert eng.pool.manager.n_free_blocks == eng.pool.manager.num_blocks


def test_perfect_draft_accepts_everything():
    """Draft == target: every offered draft token must be accepted (the
    acceptance rule is exact argmax match on the same logits)."""
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=4, seed=2, min_new=8, max_new=16)
    eng = ServeEngine(
        model, params, n_slots=2, max_len=MAX_LEN,
        draft_model=build_model(model.cfg), draft_params=params, gamma_max=4,
    )
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    eng.run()
    _assert_offline_identical(eng, model, params, rids, reqs)
    # Every observed round accepted its whole (possibly clamped) offer:
    # the controller absorbed only successes, never a break.
    assert eng.spec.hist.sum() > 0
    assert eng.spec.p > eng.spec.p0   # only pulled up, never a failure
    assert eng.spec.observations == eng.stats.spec_accepted > 0


def test_recurrent_verify_commits_exactly_accepted_prefix():
    """xLSTM state after a verify with a rejected tail must bit-match
    sequentially decoding ONLY the accepted tokens (the on-device
    acceptance chain) — state rollback correctness, not just tokens."""
    model, params = _model("xlstm-125m")
    rng = np.random.default_rng(3)
    P = 8
    prompt = rng.integers(0, model.cfg.vocab_size, size=P).astype(np.int32)
    caches = model.blank_caches(1, MAX_LEN)
    logits, caches = model.prefill_with_cache(
        params, jnp.asarray(prompt[None]), caches,
        length=jnp.asarray([P], jnp.int32),
    )
    t0 = int(jnp.argmax(logits[0, -1]))
    # Sequential reference: decode 2 accepted tokens.
    seq = caches
    tok = t0
    toks = [t0]
    for t in range(P, P + 2):
        lg, seq = model.decode_step(
            params, jnp.asarray([[tok]], jnp.int32), seq, jnp.int32(t)
        )
        tok = int(jnp.argmax(lg[0, -1]))
        toks.append(tok)
    # Verify with drafts [right, wrong, anything]: accepts exactly 1.
    wrong = (toks[2] + 1) % model.cfg.vocab_size
    inputs = jnp.asarray([[t0, toks[1], wrong, 0]], jnp.int32)
    _, committed = model.verify_with_cache(
        params, inputs, caches, jnp.asarray([4], jnp.int32),
        jnp.asarray([P], jnp.int32),
    )
    # Committed state must equal the sequential state after consuming
    # exactly [t0, toks[1]] — the accepted prefix.
    for a, b in zip(jax.tree.leaves(committed), jax.tree.leaves(seq)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Rollback under defrag + slot reuse
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_size", [None, 16])
def test_speculative_rollback_under_defrag(block_size):
    """Defragging between rounds permutes both pools (and, paged, the
    block tables holding rolled-back stale rows) — the stream must stay
    byte-identical. Regression for rollback-state/defrag interaction."""
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=5, seed=9)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=MAX_LEN, block_size=block_size,
        draft_model=build_model(model.cfg), draft_params=_perturb(params, 1e-3),
        gamma_max=3,
    )
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    defragged = 0
    while eng.step() != "done":
        act = eng.pool.active
        if act.any() and not act[: eng.pool.n_active].all():
            if eng.defrag():
                defragged += 1
            if eng.pool.manager is not None:
                eng.pool.manager.check()
    assert defragged > 0, "workload never fragmented the pool; weak test"
    _assert_offline_identical(eng, model, params, rids, reqs)
    assert eng.draft.pool.active.tolist() == eng.pool.active.tolist()


def test_speculation_does_not_starve_admissions_under_pressure():
    """Arena pressure + speculation: multi-token verify rounds pay down
    the decode-per-prefill debt by their committed tokens, so queued
    requests are still admitted while strangers generate, and blocks
    freed by speculative finishes unblock the queue."""
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=6, seed=4, min_new=6, max_new=14)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=48,
        scheduler=Scheduler(3, prefill_chunk=8, decode_per_prefill=4),
        block_size=8, arena_blocks=9,   # < the 18 a full pool would commit
        draft_model=build_model(model.cfg), draft_params=_perturb(params, 3e-4),
        gamma_max=4,
    )
    rids = [eng.submit(p, min(m, 20), arrival=a) for p, m, a in reqs]
    eng.run()
    results = dict(eng._requests)
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, min(m, 20), 48)
        assert results[rid].tokens == ref
    # Admissions interleaved with speculation: some prefill happened
    # after the first spec round (not all admissions up front).
    kinds = [k for k, _, _ in eng.events]
    first_spec = kinds.index("spec")
    assert "prefill" in kinds[first_spec:], (
        "no admission after speculation started — spec starved the queue"
    )
    assert eng.pool.manager.n_free_blocks == eng.pool.manager.num_blocks


def test_spec_round_pays_decode_debt_by_committed_tokens():
    """Scheduler unit: a verify round that commits k tokens counts as k
    decode ticks toward the decode_per_prefill obligation."""
    sched = Scheduler(2, decode_per_prefill=4)
    sched._decode_debt = 4
    sched.on_spec_round(draft_ticks=2, verify_tokens=3, emitted=3)
    assert sched._decode_debt == 2          # 3 - the 1 next_action pays
    sched.on_spec_round(draft_ticks=2, verify_tokens=3, emitted=1)
    assert sched._decode_debt == 2          # single-token round: no extra
    t = sched.clock.now
    assert t == pytest.approx(
        2 * sched.clock.cost.spec_round(2, 3)
    )


# ---------------------------------------------------------------------------
# Gamma controller
# ---------------------------------------------------------------------------

def test_choose_gamma_matches_bruteforce():
    cost = CostModel()
    ctrl = SpecController(gamma_max=6, warmup=0, p0=0.9)
    plan = ctrl.choose_gamma(cost)
    brute = min(
        range(0, 7),
        key=lambda g: ctrl.round_cost(g, cost) / expected_round_tokens(g, 0.9),
    )
    assert plan.gamma == brute > 0
    assert plan.cost_per_token == pytest.approx(
        ctrl.round_cost(plan.gamma, cost) / expected_round_tokens(plan.gamma, 0.9)
    )


def test_controller_backs_off_when_draft_costs_too_much():
    """The EXPERIMENTS caveat as an assertion: draft/target cost ratio
    near 1 makes every gamma > 0 lose, and the controller must fall back
    to plain decode (gamma = 0) except for deterministic probes."""
    expensive = CostModel(draft_ratio=0.95)
    ctrl = SpecController(gamma_max=6, warmup=0, p0=0.3, probe_every=5)
    ctrl.draft_fused = False   # recurrent draft: replay makes it worse
    gammas = [ctrl.choose_gamma(expensive).gamma for _ in range(10)]
    assert gammas.count(0) == 8
    assert gammas[4] == gammas[9] == 1      # probes keep telemetry alive


def test_controller_ewma_tracks_acceptance():
    ctrl = SpecController(gamma_max=4, alpha=0.5, p0=0.5, warmup=2)
    for _ in range(20):
        ctrl.observe(4, 4)                  # all accepted
    assert ctrl.p > 0.99
    for _ in range(20):
        ctrl.observe(0, 4)                  # chain breaks immediately
    assert ctrl.p < 0.01
    # Censoring: a break records ONE failure, not (offered - accepted).
    ctrl2 = SpecController(gamma_max=4, alpha=0.5, p0=0.5)
    ctrl2.observe(1, 4)
    assert ctrl2.observations == 2          # 1 success + 1 failure
    with pytest.raises(ValueError):
        ctrl2.observe(5, 4)


def test_hedged_gamma_pricing_uses_expected_kth():
    """The (k, beta) mapping: hedged round cost must equal the paper's
    order-statistics formula with beta scaled by the verify width, and
    the joint brute force must find the argmin over (gamma, n_h)."""
    dm = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    kw = dict(draft_time=0.01, beta_unit=0.1, cost_per_replica=0.03)
    got = hedged_round_cost(dm, 3, 4, **kw)
    want = 4 * 0.01 + expected_kth(dm, 3, 1, 0.5) + 0.03 * 3
    assert got == pytest.approx(want)

    ctrl = SpecController(gamma_max=5, warmup=0, p0=0.85)
    plan = ctrl.choose_hedged(dm, n_max=6, **kw)
    brute = min(
        ((g, n) for g in range(6) for n in range(1, 7)),
        key=lambda gn: hedged_round_cost(dm, gn[1], gn[0], **kw)
        / expected_round_tokens(gn[0], 0.85),
    )
    assert (plan.gamma, plan.n_h) == brute

    # Load extrapolates past beta = 1 (no silent clamp, no domain
    # crash): widening the verify window must keep costing latency for
    # BOTH delay models — Def. 2 rejects beta > 1 outright, so the
    # pricing extrapolates from beta = 1 via expected_kth_derivative.
    from repro.core.delay_models import GeneralizedDelayModel

    big = dict(kw, beta_unit=0.4)          # beta = 1.2, 1.6, 2.0
    for model in (dm, GeneralizedDelayModel(lambda_x=5.0, lambda_y=2.0, x=0.02)):
        lat = [hedged_round_cost(model, 2, g, **big) - g * big["draft_time"]
               for g in (2, 3, 4)]
        assert lat[0] < lat[1] < lat[2]
