"""End-to-end integration: the adaptive-(k, beta) train loop on a tiny LM.

Covers: learning progress, stage advancement (one compiled shape per
beta), fastest-k masking metrics, failure injection, checkpoint resume,
and gradient-accumulation equivalence.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import DiagnosticConfig, SimplifiedDelayModel, StrategyConfig
from repro.data import StagedBatcher, TokenStream
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime.steps import make_train_step
from repro.runtime.train_loop import FaultEvent, TrainLoopConfig, train


def _tiny():
    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, max_seq_len=64,
    )
    return cfg, build_model(cfg)


def _setup(n=4, global_batch=16, seq_len=32):
    cfg, model = _tiny()
    strategy = StrategyConfig(
        "adaptive_kbeta", n=n, s=global_batch // n, k_max=n // 2,
        beta_grid=(0.5, 1.0),
        diagnostic=DiagnosticConfig(kind="loss", rel_tol=0.05, min_iters=5,
                                    consecutive=2),
    )
    delay = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    batcher = StagedBatcher(TokenStream(cfg.vocab_size, seed=0), n_workers=n,
                            global_batch=global_batch, seq_len=seq_len)
    return cfg, model, strategy, delay, batcher


def test_loop_learns_and_advances_stages():
    cfg, model, strategy, delay, batcher = _setup()
    out = train(model, get_optimizer("adamw"), strategy, delay, batcher,
                TrainLoopConfig(total_steps=80, log_every=0))
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.98
    stages = {(h["k"], h["beta"]) for h in hist}
    assert len(stages) >= 2, "controller must advance at least one stage"
    # one compiled program per distinct batch shape (per beta)
    assert 1 <= len(out["compiled_shapes"]) <= 2


def test_loop_failure_injection_reduces_n():
    cfg, model, strategy, delay, batcher = _setup()
    out = train(model, get_optimizer("adamw"), strategy, delay, batcher,
                TrainLoopConfig(total_steps=30, log_every=0,
                                fail_worker_at=10, fail_worker_id=2))
    assert out["controller"].cfg.n == 3
    # training continued and stayed finite after the failure
    assert np.isfinite([h["loss"] for h in out["history"]]).all()


def test_loop_checkpoint_resume_exact():
    cfg, model, strategy, delay, batcher = _setup()
    with tempfile.TemporaryDirectory() as d:
        out1 = train(model, get_optimizer("adamw"), strategy, delay, batcher,
                     TrainLoopConfig(total_steps=40, log_every=0,
                                     checkpoint_dir=d, checkpoint_every=20))
        out2 = train(model, get_optimizer("adamw"), strategy, delay, batcher,
                     TrainLoopConfig(total_steps=50, log_every=0,
                                     checkpoint_dir=d, checkpoint_every=20))
        assert out2["history"][0]["step"] == 40


def test_grad_accumulation_matches_direct():
    """accum_steps=2 must reproduce the single-batch gradient step."""
    cfg, model = _tiny()
    opt = get_optimizer("sgd")
    rng = jax.random.PRNGKey(0)
    params = model.init(rng, dtype_override="float32")
    opt_state = opt.init(params)
    n = 4
    B, S = 8, 16
    batch = {
        "inputs": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "worker_mask": jnp.array([1.0, 0.0, 1.0, 1.0]),
        "lr": jnp.float32(0.1),
    }
    step1 = make_train_step(model, opt, clip_norm=None)
    step2 = make_train_step(model, opt, clip_norm=None, accum_steps=2)
    p1, _, m1 = step1(params, opt_state, batch)
    p2, _, m2 = step2(params, opt_state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_resume_replays_identical_history():
    """Exact resume: the resumed run's history must equal the
    uninterrupted run's tail field-for-field (loss, stage, sim-time,
    fleet) because controller state, tracker state, membership, and both
    RNG streams round-trip through the checkpoint."""
    cfg, model, strategy, delay, batcher = _setup()
    events = [FaultEvent(step=8, kind="slow", worker=1, factor=3.0),
              FaultEvent(step=15, kind="fail", worker=2),
              FaultEvent(step=32, kind="rejoin", worker=2)]
    with tempfile.TemporaryDirectory() as d:
        mk = lambda: TrainLoopConfig(total_steps=44, log_every=0,
                                     checkpoint_dir=d, checkpoint_every=20,
                                     events=events)
        out1 = train(model, get_optimizer("adamw"), strategy, delay, batcher,
                     mk())
        # Fresh everything: all live state must come from the checkpoint.
        cfg2, model2, strategy2, delay2, batcher2 = _setup()
        out2 = train(model2, get_optimizer("adamw"), strategy2, delay2,
                     batcher2, mk())
        tail = [h for h in out1["history"] if h["step"] >= 40]
        assert out2["history"][0]["step"] == 40
        assert len(out2["history"]) == len(tail)
        for a, b in zip(tail, out2["history"]):
            assert a == b, f"resume diverged at step {a['step']}"
        assert out2["controller"].cfg.n == out1["controller"].cfg.n
        np.testing.assert_array_equal(out2["alive"], out1["alive"])


def test_rejoin_restores_fleet_and_k_max():
    cfg, model, strategy, delay, batcher = _setup()
    out = train(model, get_optimizer("adamw"), strategy, delay, batcher,
                TrainLoopConfig(total_steps=30, log_every=0,
                                events=[FaultEvent(5, "fail", 1),
                                        FaultEvent(15, "rejoin", 1)]))
    ctrl = out["controller"]
    assert ctrl.cfg.n == strategy.n, "rejoin must restore n"
    assert ctrl.cfg.k_max == strategy.k_max, "rejoin must restore k_max cap"
    assert out["alive"].all()
    n_by_step = {h["step"]: h["n_workers"] for h in out["history"]}
    assert n_by_step[10] == strategy.n - 1
    assert n_by_step[20] == strategy.n


def test_loop_fits_delay_model_from_censored_telemetry_only():
    """oracle_to_controller=False: every (k, beta) decision prices off a
    model fitted purely from the k order statistics the loop waited for."""
    cfg, model, strategy, delay, batcher = _setup()
    out = train(model, get_optimizer("adamw"), strategy, delay, batcher,
                TrainLoopConfig(total_steps=80, log_every=0,
                                estimate_model=True,
                                oracle_to_controller=False))
    ctrl = out["controller"]
    assert ctrl.oracle_model is None
    assert sum(ctrl._rt_censored) > 0, "fastest-k telemetry must be censored"
    est = ctrl.current_model()
    assert est is not None
    # True lambda_y = 1.0; the censored fit must land in its vicinity
    # even though most workers' times were never observed.
    assert 0.5 < est.lambda_y < 2.0
    stages = {(h["k"], h["beta"]) for h in out["history"]}
    assert len(stages) >= 2, "fitted model must still drive stage advances"


def test_batcher_resizes_batch_for_current_fleet():
    cfg, model, strategy, delay, batcher = _setup(n=4, global_batch=16)
    full = batcher.batch_for_stage(1.0)["inputs"].shape[0]
    shrunk = batcher.batch_for_stage(1.0, n_workers=3)["inputs"].shape[0]
    assert full == 16
    assert shrunk == 12, "per-worker share stays fixed; batch tracks fleet"
    assert batcher.batch_shape(1.0, n_workers=3)[0] == 12
    with pytest.raises(ValueError):
        batcher.batch_for_stage(1.0, n_workers=0)


def test_straggler_demotion_in_loop():
    cfg, model, strategy, delay, batcher = _setup()

    class SlowWorker(SimplifiedDelayModel):
        def sample(self, rng, n, beta):
            z = super().sample(rng, n, beta)
            return np.concatenate([z[:1] * 12.0, z[1:]])

    slow = SlowWorker(lambda_y=1.0, x=0.05)
    out = train(model, get_optimizer("adamw"), strategy, slow, batcher,
                TrainLoopConfig(total_steps=40, log_every=0,
                                demote_after_ewma=6.0))
    assert out["controller"].cfg.n == 3, "persistent straggler demoted"
