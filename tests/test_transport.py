"""Transport fabric semantics + migration-ticket integrity.

Unit-level pins for ``serve.transport``: the declarative fault plan
(drop/dup/delay/reorder/corrupt/partition, all JSON round-trippable),
the at-least-once layer (ack + retransmit, receiver dedup, give-up),
and the end-to-end ticket checksum (sealed at export, verified at
import, deadline excluded by design). The system-level consequences —
byte identity and zero drops under every fault mix — are searched by
tools/chaos_search.py and pinned in tests/test_chaos_search.py.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    FaultDirective,
    Partition,
    ServeEngine,
    TicketIntegrityError,
    Transport,
    TransportFaults,
    TransportGaveUp,
    generate_offline,
    ticket_checksum,
)
from repro.serve.transport import FE, Cancel, Submit

MAX_LEN = 64


# ---------------------------------------------------------------------------
# Fault plan: validation + JSON round-trip
# ---------------------------------------------------------------------------

def test_fault_directive_validation():
    with pytest.raises(ValueError):
        FaultDirective(src="fe", dst="r0", op="explode", nth=0)
    with pytest.raises(ValueError):
        FaultDirective(src="fe", dst="r0", op="drop", nth=-1)
    with pytest.raises(ValueError):
        FaultDirective(src="fe", dst="r0", op="delay", nth=0, ticks=-2)


def test_partition_validation():
    with pytest.raises(ValueError):
        Partition(src="fe", dst="r0", t0=5, t1=5)
    with pytest.raises(ValueError):
        Partition(src="fe", dst="r0", t0=-1, t1=5)


def test_fault_plan_json_roundtrip():
    plan = TransportFaults(
        [FaultDirective("fe", "r0", "drop", 0),
         FaultDirective("r1", "fe", "delay", 3, ticks=4)],
        [Partition("fe", "r2", 10, 20)],
    )
    back = TransportFaults.from_dict(plan.as_dict())
    assert back.as_dict() == plan.as_dict()
    assert len(back) == 3
    assert back.ops_for("fe", "r0", 0) == plan.ops_for("fe", "r0", 0)
    assert back.partitioned("fe", "r2", 15) and not back.partitioned(
        "fe", "r2", 20
    )


# ---------------------------------------------------------------------------
# Channel + reliability layer (host-only, no model)
# ---------------------------------------------------------------------------

def _drain(t: Transport, until: int = 60):
    """Run the plane's delivery loop standalone: pump + receive on both
    ends each tick, collecting what r0 sees."""
    got = []
    for tick in range(until):
        t.pump(tick)
        got += [m.payload for m in t.receive("r0", tick)]
        t.receive(FE, tick)     # strip acks so retransmission stops
    return got


def test_drop_without_reliability_loses_the_message():
    t = Transport(1, TransportFaults([FaultDirective("fe", "r0", "drop", 0)]),
                  reliable=False)
    t.send(FE, "r0", Cancel(7, 0), 0)
    assert _drain(t) == []
    assert t.stats()["dropped"] == 1 and not t.busy()


def test_reliable_retransmit_survives_drop_exactly_once():
    t = Transport(1, TransportFaults([FaultDirective("fe", "r0", "drop", 0)]),
                  base_rto_ticks=1)
    t.send(FE, "r0", Cancel(7, 0), 0)
    got = _drain(t)
    assert [p.gid for p in got] == [7]
    s = t.stats()
    # n_sent counts transmissions, so the retransmission shows up as a
    # second send on the fe->r0 link (plus the reverse-direction ack).
    assert s["dropped"] == 1 and s["sent"] >= 3 and not t.busy()


def test_duplicate_suppressed_by_receiver_dedup():
    t = Transport(1, TransportFaults([FaultDirective("fe", "r0", "dup", 0)]))
    t.send(FE, "r0", Cancel(3, 1), 0)
    got = _drain(t)
    assert [(p.gid, p.attempt) for p in got] == [(3, 1)]
    assert t.stats()["duplicated"] == 1 and not t.busy()


def test_duplicate_delivered_twice_without_dedup():
    t = Transport(1, TransportFaults([FaultDirective("fe", "r0", "dup", 0)]),
                  dedup=False)
    t.send(FE, "r0", Cancel(3, 1), 0)
    got = _drain(t)
    assert [(p.gid, p.attempt) for p in got] == [(3, 1), (3, 1)]


def test_delay_holds_delivery_until_the_tick():
    t = Transport(1, TransportFaults(
        [FaultDirective("fe", "r0", "delay", 0, ticks=5)]))
    t.send(FE, "r0", Cancel(0, 0), 0)
    assert t.receive("r0", 4) == []
    assert [m.payload.gid for m in t.receive("r0", 5)] == [0]


def test_reorder_swaps_adjacent_messages():
    t = Transport(1, TransportFaults(
        [FaultDirective("fe", "r0", "reorder", 0, ticks=2)]), reliable=False)
    t.send(FE, "r0", Cancel(0, 0), 0)
    t.send(FE, "r0", Cancel(1, 0), 0)
    got = _drain(t)
    assert [p.gid for p in got] == [1, 0]


def test_partition_heals_and_retransmit_gets_through():
    t = Transport(1, TransportFaults([], [Partition("fe", "r0", 0, 6)]),
                  base_rto_ticks=1)
    t.send(FE, "r0", Cancel(9, 0), 0)
    got = _drain(t)
    assert [p.gid for p in got] == [9] and not t.busy()


def test_unhealed_partition_raises_gave_up():
    t = Transport(1, TransportFaults([], [Partition("fe", "r0", 0, 10**6)]),
                  base_rto_ticks=1, max_attempts=3)
    t.send(FE, "r0", Cancel(0, 0), 0)
    with pytest.raises(TransportGaveUp):
        for tick in range(10_000):
            t.pump(tick)


def test_forget_endpoint_clears_traffic_both_ways():
    t = Transport(1, None, base_rto_ticks=1)
    t.send(FE, "r0", Cancel(0, 0), 0)
    t.send("r0", FE, Cancel(1, 0), 0)
    t.forget_endpoint("r0")
    assert not t.busy()
    assert t.receive("r0", 1) == [] and t.receive(FE, 1) == []
    # sends to a dead endpoint are silently dropped, not queued
    t.send(FE, "r0", Cancel(2, 0), 2)
    assert not t.busy()
    t.revive_endpoint("r0")
    t.send(FE, "r0", Cancel(3, 0), 3)
    assert [p.gid for p in _drain(t)] == [3]


def test_corrupt_nonticket_degrades_to_drop():
    """Link-level corruption on anything but a migration ticket is a
    CRC failure: the message is discarded (and retransmission recovers
    it when the reliability layer is on)."""
    t = Transport(1, TransportFaults(
        [FaultDirective("fe", "r0", "corrupt", 0)]), reliable=False)
    t.send(FE, "r0", Submit(0, 0, np.arange(4, dtype=np.int32), 8, 0.0, None),
           0)
    assert _drain(t) == []
    # counted as a loss, not a delivered mutation — ``corrupted`` only
    # counts payloads mutated in flight AND delivered (tickets)
    s = t.stats()
    assert s["dropped"] == 1 and s["corrupted"] == 0


# ---------------------------------------------------------------------------
# Migration ticket integrity (sealed at export, verified at import)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _exported_ticket(model, params):
    src = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, block_size=8)
    prompt = np.random.default_rng(5).integers(
        0, model.cfg.vocab_size, 12
    ).astype(np.int32)
    rid = src.submit(prompt, 10)
    while len(src.request(rid).tokens) < 3:
        src.step()
    return src.export_request(rid), prompt


def test_export_seals_and_import_verifies(model_and_params):
    model, params = model_and_params
    ticket, prompt = _exported_ticket(model, params)
    assert ticket.checksum is not None
    assert ticket.checksum == ticket_checksum(ticket)
    dst = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, block_size=8)
    rid = dst.import_request(ticket)
    assert rid is not None
    out = dst.run()
    assert out[rid].tokens == generate_offline(model, params, prompt, 10,
                                               MAX_LEN)


def test_tampered_ticket_rejected_before_allocation(model_and_params):
    model, params = model_and_params
    ticket, _ = _exported_ticket(model, params)
    toks = list(ticket.tokens)
    toks[-1] ^= 1
    evil = dataclasses.replace(ticket, tokens=tuple(toks))
    dst = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, block_size=8)
    with pytest.raises(TicketIntegrityError) as e:
        dst.import_request(evil)
    assert ticket.checksum[:12] in str(e.value)
    # reject-and-requeue contract: the dest engine is untouched
    assert dst.pool.n_active == 0 and not dst.has_work


def test_deadline_restamp_does_not_break_the_seal(model_and_params):
    """Absolute deadlines are clock-local — the receiving replica
    legitimately rewrites them in flight, so they are excluded from the
    checksum by design."""
    model, params = model_and_params
    ticket, _ = _exported_ticket(model, params)
    restamped = dataclasses.replace(ticket, deadline=123.456)
    assert ticket_checksum(restamped) == ticket.checksum
    dst = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, block_size=8)
    assert dst.import_request(restamped) is not None
