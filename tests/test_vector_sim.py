"""Equivalence suite: batched engine vs the scalar reference oracle.

``simulate_batch`` must reproduce ``simulate`` lane-for-lane: both
consume the identical per-seed two-stream RNG layout (DESIGN.md §9), so
trajectories agree to FP roundoff (the batched gradient sums in a
different order) and stage decisions agree exactly.
"""

import math

import numpy as np
import pytest

from repro.core import (
    DiagnosticConfig,
    GeneralizedDelayModel,
    SimplifiedDelayModel,
    StrategyConfig,
    LinregProblem,
    simulate,
    simulate_batch,
    stage_table,
)
from repro.core.controller import Controller
from repro.core.order_stats import _binom_tail

GRID = (0.2, 0.4, 0.6, 0.8, 1.0)
N, S = 10, 10
MODELS = {
    "simplified": SimplifiedDelayModel(lambda_y=1.0, x=0.01),
    "generalized": GeneralizedDelayModel(lambda_x=2.0, lambda_y=1.0, x=0.01),
}
STRATEGIES = ("naive", "fastest_k", "adaptive_k", "adaptive_kbeta")


@pytest.fixture(scope="module")
def problem():
    return LinregProblem.generate(v=N * S, d=10, n_workers=N, seed=1)


def _cfg(strategy: str) -> StrategyConfig:
    return StrategyConfig(
        strategy,
        n=N,
        s=S,
        k_max=5,
        k0=2,
        beta0=0.4 if strategy == "fastest_k" else None,
        beta_grid=GRID,
    )


def _assert_lane_equal(scalar, lane, *, context=""):
    __tracebackhide__ = True
    assert scalar.times.shape == lane.times.shape, context
    for field in ("times", "gaps", "comp_at_eval", "comm_at_eval"):
        np.testing.assert_allclose(
            getattr(scalar, field),
            getattr(lane, field),
            rtol=1e-7,
            atol=1e-10,
            err_msg=f"{context}: {field}",
        )
    assert [(i, st.k, st.beta) for i, st in scalar.stage_log] == [
        (i, st.k, st.beta) for i, st in lane.stage_log
    ], context
    assert scalar.iterations == lane.iterations, context
    assert scalar.reached == lane.reached, context
    assert math.isclose(scalar.runtime, lane.runtime, rel_tol=1e-7), context
    assert math.isclose(scalar.comp_cost, lane.comp_cost, rel_tol=1e-12), context
    assert math.isclose(scalar.comm_cost, lane.comm_cost, rel_tol=1e-12), context


@pytest.mark.parametrize("model_name", sorted(MODELS))
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_per_seed_equivalence(problem, strategy, model_name):
    model = MODELS[model_name]
    cfg = _cfg(strategy)
    batch = simulate_batch(
        problem, cfg, model, seeds=3, max_iters=1200, eval_every=10
    )
    for seed in range(3):
        scalar = simulate(
            problem, cfg, model, seed=seed, max_iters=1200, eval_every=10
        )
        _assert_lane_equal(
            scalar,
            batch.lane(seed),
            context=f"{strategy}/{model_name}/seed{seed}",
        )


@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_oracle_switch_times_equivalence(problem, model_name):
    model = MODELS[model_name]
    cfg = _cfg("adaptive_kbeta")
    times = [2.0, 4.0, 5.5, 8.0, 11.0, 15.0]
    batch = simulate_batch(
        problem, cfg, model, seeds=3, max_iters=1200, eval_every=10,
        oracle_switch_times=times,
    )
    for seed in range(3):
        scalar = simulate(
            problem, cfg, model, seed=seed, max_iters=1200, eval_every=10,
            oracle_switch_times=times,
        )
        _assert_lane_equal(
            scalar, batch.lane(seed), context=f"oracle/{model_name}/seed{seed}"
        )
    # The oracle schedule must actually have advanced stages.
    assert len(batch.stage_logs[0]) > 1


@pytest.mark.parametrize("kind", ["distance", "pflug", "loss"])
def test_diagnostic_kinds_equivalence(problem, kind):
    """Each batched diagnostic port fires at the same iterations as its
    scalar counterpart (per-lane switch decisions are part of the
    equivalence contract)."""
    model = MODELS["simplified"]
    cfg = StrategyConfig(
        "adaptive_kbeta", n=N, s=S, k_max=5, beta_grid=GRID,
        diagnostic=DiagnosticConfig(kind=kind),
    )
    batch = simulate_batch(
        problem, cfg, model, seeds=2, max_iters=1000, eval_every=10
    )
    for seed in range(2):
        scalar = simulate(
            problem, cfg, model, seed=seed, max_iters=1000, eval_every=10
        )
        _assert_lane_equal(
            scalar, batch.lane(seed), context=f"diag-{kind}/seed{seed}"
        )
    # distance/loss must actually exercise switching at these settings;
    # pflug legitimately never fires here (the calibrated eta keeps
    # consecutive gradients positively correlated), so for it the
    # equivalence of the no-switch trajectories is the whole check.
    if kind != "pflug":
        assert any(len(log) > 1 for log in batch.stage_logs), kind


def test_pflug_advancement_equivalence():
    """At a step size near the stability limit consecutive gradients
    anti-correlate fast, so Pflug actually drives stage switches — the
    batched advancement path must match the scalar one."""
    base = LinregProblem.generate(v=N * S, d=10, n_workers=N, seed=1)
    lam_max = float(np.linalg.eigvalsh(2.0 * base.X.T @ base.X / base.v).max())
    prob = LinregProblem.generate(
        v=N * S, d=10, n_workers=N, seed=1, eta=1.2 / lam_max
    )
    cfg = StrategyConfig(
        "adaptive_kbeta", n=N, s=S, k_max=5, beta_grid=GRID,
        diagnostic=DiagnosticConfig(kind="pflug", burn_in=16),
    )
    model = MODELS["simplified"]
    batch = simulate_batch(prob, cfg, model, seeds=2, max_iters=600, eval_every=10)
    assert all(len(log) > 1 for log in batch.stage_logs)
    for seed in range(2):
        scalar = simulate(prob, cfg, model, seed=seed, max_iters=600, eval_every=10)
        _assert_lane_equal(
            scalar, batch.lane(seed), context=f"pflug-hot/seed{seed}"
        )


def test_target_gap_early_exit(problem):
    model = MODELS["simplified"]
    cfg = _cfg("adaptive_kbeta")
    e0 = problem.gap(np.zeros(problem.d))
    target = e0 * 0.05
    batch = simulate_batch(
        problem, cfg, model, seeds=4, max_iters=3000, eval_every=10,
        target_gap=target,
    )
    assert batch.reached.all()
    for seed in range(4):
        scalar = simulate(
            problem, cfg, model, seed=seed, max_iters=3000, eval_every=10,
            target_gap=target,
        )
        _assert_lane_equal(
            scalar, batch.lane(seed), context=f"target_gap/seed{seed}"
        )
    # Lanes freeze at different iterations; the stacked arrays keep each
    # lane's valid prefix length.
    assert batch.times.shape[1] == int(batch.n_evals.max())


def test_explicit_seed_sequence(problem):
    model = MODELS["simplified"]
    cfg = _cfg("adaptive_k")
    batch = simulate_batch(
        problem, cfg, model, seeds=(7, 3), max_iters=400, eval_every=10
    )
    assert batch.seeds == (7, 3)
    for i, seed in enumerate((7, 3)):
        scalar = simulate(
            problem, cfg, model, seed=seed, max_iters=400, eval_every=10
        )
        _assert_lane_equal(scalar, batch.lane(i), context=f"seedseq/{seed}")


def test_w0_broadcast(problem):
    model = MODELS["simplified"]
    cfg = _cfg("fastest_k")
    w0 = np.full(problem.d, 0.1)
    batch = simulate_batch(
        problem, cfg, model, seeds=2, max_iters=200, eval_every=10, w0=w0
    )
    scalar = simulate(
        problem, cfg, model, seed=1, max_iters=200, eval_every=10, w0=w0
    )
    _assert_lane_equal(scalar, batch.lane(1), context="w0")


def test_estimate_model_unsupported(problem):
    with pytest.raises(ValueError, match="estimate"):
        simulate_batch(
            problem, _cfg("adaptive_kbeta"), MODELS["simplified"],
            seeds=2, max_iters=10, estimate_model=True,
        )


def test_mismatched_partitioning_rejected(problem):
    cfg = StrategyConfig("adaptive_k", n=N + 1, s=S, k_max=5)
    with pytest.raises(ValueError, match="partition"):
        simulate_batch(problem, cfg, MODELS["simplified"], seeds=2, max_iters=10)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_stage_table_matches_controller_walk(strategy):
    model = MODELS["simplified"]
    cfg = _cfg(strategy)
    table = stage_table(cfg, model)
    ctrl = Controller(cfg, model=model)
    walked = [ctrl.stage]
    while ctrl.advance() is not None:
        walked.append(ctrl.stage)
    assert [(st.k, st.beta) for st in table] == [(st.k, st.beta) for st in walked]
    # phi = k * beta must be non-decreasing along every table.
    phis = [st.phi for st in table]
    assert all(b >= a - 1e-12 for a, b in zip(phis, phis[1:]))


# ---------------------------------------------------------------------------
# _binom_tail vectorization (order_stats satellite)
# ---------------------------------------------------------------------------


def _binom_tail_loop(p, n, k):
    """The original per-j loop, kept as the test reference."""
    p = np.clip(np.asarray(p, dtype=np.float64), 0.0, 1.0)
    out = np.zeros_like(p)
    logp = np.log(np.clip(p, 1e-300, 1.0))
    log1mp = np.log1p(-np.clip(p, 0.0, 1.0 - 1e-16))
    for j in range(k, n + 1):
        logc = math.lgamma(n + 1) - math.lgamma(j + 1) - math.lgamma(n - j + 1)
        out += np.exp(logc + j * logp + (n - j) * log1mp)
    out = np.where(p >= 1.0 - 1e-16, 1.0, out)
    return np.clip(out, 0.0, 1.0)


@pytest.mark.parametrize("n,k", [(1, 1), (5, 1), (20, 7), (20, 20), (200, 63)])
def test_binom_tail_matches_loop(n, k):
    p = np.concatenate([
        np.array([0.0, 1e-17, 1e-8, 0.5, 1.0 - 1e-17, 1.0]),
        np.linspace(0.001, 0.999, 101),
    ])
    np.testing.assert_allclose(
        _binom_tail(p, n, k), _binom_tail_loop(p, n, k), rtol=1e-12, atol=1e-300
    )


def test_binom_tail_edges():
    # Values outside [0, 1] are clipped, p == 1 gives exactly 1.
    out = _binom_tail(np.array([-0.5, 0.0, 1.0, 1.5]), 10, 3)
    assert out[0] == 0.0 and out[1] == 0.0
    assert out[2] == 1.0 and out[3] == 1.0
    # Monotone non-decreasing in p.
    p = np.linspace(0, 1, 201)
    tail = _binom_tail(p, 15, 6)
    assert np.all(np.diff(tail) >= -1e-12)
    # 2-D input broadcasts.
    p2 = p.reshape(3, 67)
    np.testing.assert_allclose(_binom_tail(p2, 15, 6), tail.reshape(3, 67))
