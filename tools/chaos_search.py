#!/usr/bin/env python
"""Chaos-search: randomized fault-schedule exploration for the serving
plane, with invariant oracles and delta-debugged minimal repros.

The serving plane is deterministic virtual time end to end — same
workload + same node-fault schedule + same transport-fault plan means
the same token streams, the same wire history, the same trace. That
turns this script into a model checker in the Jepsen style: sample a
few hundred seeded chaos schedules (replica fail / slow / rejoin /
drain × message drop / dup / reorder / delay / corrupt / one-way
partition), run each against the full oracle set, and when one fails,
shrink the schedule one atom at a time (ddmin) to a minimal JSON repro
that replays bit-for-bit.

Invariant oracles (each failure names the oracle + detail):

* ``liveness``      — the run finishes (no stall past ``max_ticks``, no
                      stranded frontend, no transport give-up);
* ``zero_drop``     — no request exhausts its retry budget (the
                      generator bounds chaos below the budget, so a
                      drop means the plane burned retries it should not
                      have);
* ``byte_identity`` — every final stream equals the fault-free offline
                      reference exactly;
* ``no_leaks``      — after drain: every slot pool empty, every paged
                      arena fully free, no live engine requests, router
                      in-flight counts zero, transport drained;
* ``trace``         — ``repro.obs.validate_trace`` passes and no span
                      is left open;
* ``conservation``  — every submitted gid reaches exactly one terminal
                      state and submitted == completed + dropped;
* ``exactly_once``  — no ``(gid, attempt)`` admitted twice on one
                      replica (the receiver-side effect dedup must
                      catch duplicated/retransmitted submits);
* ``block_conservation`` — every replica's paged arena passes
                      ``BlockManager.audit()`` after drain: each
                      block's refcount equals its live table
                      references, and free + referenced partition the
                      arena exactly (no block leaked, none doubly
                      freed). Runs with or without prefix sharing —
                      under sharing it is the end-to-end check on the
                      copy-on-write ledger.

Campaigns run with the reliability layer ON and must pass every oracle
(CI gates on this). With ``--no-reliable`` or ``--no-dedup`` the same
harness demonstrates WHY the layer exists: a single dropped data message
strands the plane, a single duplicated submit double-admits — and the
shrinker reduces whatever it finds to the one directive that did it
(pinned in tests/test_chaos_search.py). ``--leak-blocks`` seeds a
refcount bug on the engine's cancel path (one block dropped without a
free) so the conservation oracle has teeth: only cancel-bearing
schedules trip it, and ddmin shrinks the repro to that one atom.
``--prefix-sharing`` runs the whole campaign on copy-on-write fleets.

Usage:
    python tools/chaos_search.py --schedules 500            # full campaign
    python tools/chaos_search.py --schedules 120 --fast     # CI gate
    python tools/chaos_search.py --replay chaos_repros/repro_....json
    python tools/chaos_search.py --schedules 40 --fast --no-reliable \
        --expect-violations                                 # demo mode

Exit code 0 iff the campaign matches expectations (no violations, or
``--expect-violations`` and at least one found + shrunk + replayed).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax                                                   # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.core.delay_models import SimplifiedDelayModel      # noqa: E402
from repro.models import build_model                          # noqa: E402
from repro.obs import Observability, validate_trace           # noqa: E402
from repro.runtime.faults import FaultEvent                   # noqa: E402
from repro.serve import (                                     # noqa: E402
    FaultDirective,
    Frontend,
    Partition,
    Replica,
    TransportFaults,
    generate_offline,
)

REPRO_SCHEMA = 1
MAX_LEN = 64
N_REPLICAS = 3
N_SLOTS = 2
BLOCK_SIZE = 8


# ---------------------------------------------------------------------------
# Schedules: node events + transport plan, JSON round-trip, ddmin atoms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Schedule:
    """One complete chaos schedule — pure data, every entry individually
    removable (the shrinker's atom set is the concatenation of the three
    lists)."""

    events: List[FaultEvent]
    directives: List[FaultDirective]
    partitions: List[Partition]
    # Dispatch regime, NOT a removable atom: cheap hedging fans every
    # request across the fleet (losses masked by redundancy — tests the
    # cancel/dedup machinery), expensive hedging forces singleton
    # dispatch (every guarantee rides on the at-least-once layer).
    cost_per_replica: float = 0.001

    def as_dict(self) -> dict:
        return {
            "events": [e.as_dict() for e in self.events],
            "directives": [d.as_dict() for d in self.directives],
            "partitions": [p.as_dict() for p in self.partitions],
            "cost_per_replica": self.cost_per_replica,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Schedule":
        return cls(
            events=[FaultEvent.from_dict(x) for x in d.get("events", ())],
            directives=[FaultDirective.from_dict(x)
                        for x in d.get("directives", ())],
            partitions=[Partition.from_dict(x)
                        for x in d.get("partitions", ())],
            cost_per_replica=float(d.get("cost_per_replica", 0.001)),
        )

    def atoms(self) -> List[Tuple[str, int]]:
        return ([("event", i) for i in range(len(self.events))]
                + [("directive", i) for i in range(len(self.directives))]
                + [("partition", i) for i in range(len(self.partitions))])

    def without(self, removed: Sequence[Tuple[str, int]]) -> "Schedule":
        rm = set(removed)
        return Schedule(
            events=[e for i, e in enumerate(self.events)
                    if ("event", i) not in rm],
            directives=[d for i, d in enumerate(self.directives)
                        if ("directive", i) not in rm],
            partitions=[p for i, p in enumerate(self.partitions)
                        if ("partition", i) not in rm],
            cost_per_replica=self.cost_per_replica,
        )

    def size(self) -> int:
        return len(self.events) + len(self.directives) + len(self.partitions)


def sample_schedule(rng: np.random.Generator) -> Schedule:
    """Draw one schedule. Liveness is kept SATISFIABLE by construction:
    replica 0 is never failed or drained (the plane cannot survive
    losing the whole fleet with nothing scheduled to rejoin — that is
    an operator error, not a protocol bug worth searching for), and the
    node-event count stays well below the frontend's retry budget."""
    events: List[FaultEvent] = []
    for _ in range(int(rng.integers(0, 4))):
        kind = str(rng.choice(["fail", "slow", "rejoin", "drain"]))
        worker = (int(rng.integers(1, N_REPLICAS))
                  if kind in ("fail", "drain")
                  else int(rng.integers(0, N_REPLICAS)))
        events.append(FaultEvent(
            step=int(rng.integers(0, 120)),
            kind=kind,
            worker=worker,
            factor=float(np.round(rng.uniform(1.5, 4.0), 3)),
        ))
    links = [("fe", f"r{i}") for i in range(N_REPLICAS)] + [
        (f"r{i}", "fe") for i in range(N_REPLICAS)
    ]
    directives: List[FaultDirective] = []
    for _ in range(int(rng.integers(0, 5))):
        src, dst = links[int(rng.integers(0, len(links)))]
        op = str(rng.choice(["drop", "dup", "delay", "reorder", "corrupt"]))
        # Low-biased ordinals: these links carry a handful of messages,
        # so a uniform draw over [0, 60) mostly misses. Keep a tail so
        # late retransmissions stay reachable.
        nth = (int(rng.integers(0, 6)) if rng.random() < 0.7
               else int(rng.integers(0, 60)))
        directives.append(FaultDirective(
            src=src, dst=dst, op=op, nth=nth,
            ticks=int(rng.integers(1, 7)),
        ))
    partitions: List[Partition] = []
    if rng.random() < 0.4:
        src, dst = links[int(rng.integers(0, len(links)))]
        t0 = int(rng.integers(0, 100))
        partitions.append(Partition(
            src=src, dst=dst, t0=t0, t1=t0 + int(rng.integers(4, 21)),
        ))
    cost = float(rng.choice([0.001, 10.0]))
    return Schedule(events, directives, partitions, cost_per_replica=cost)


# ---------------------------------------------------------------------------
# Workload + oracles
# ---------------------------------------------------------------------------

class Workload:
    """A fixed request set over a fixed fleet geometry, with fault-free
    offline references computed once. The model/params are shared across
    every run of a campaign, so jitted engine steps compile once
    (``model_scoped_cache``)."""

    def __init__(self, arch: str = "smollm-135m", n_requests: int = 6,
                 seed: int = 1, prefix_sharing: bool = False):
        cfg = get_config(arch).reduced()
        self.arch = arch
        self.n_requests = n_requests
        self.seed = seed
        self.prefix_sharing = bool(prefix_sharing)
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(seed)
        self.requests = []
        for i in range(n_requests):
            p = int(rng.integers(4, 16))
            m = int(rng.integers(6, 12))
            prompt = rng.integers(0, cfg.vocab_size, size=p).astype(np.int32)
            self.requests.append((prompt, m, i * 0.002))
        self.refs = [
            generate_offline(self.model, self.params, p, m, MAX_LEN)
            for p, m, _ in self.requests
        ]

    def as_dict(self) -> dict:
        return {"arch": self.arch, "n_requests": self.n_requests,
                "seed": self.seed, "n_replicas": N_REPLICAS,
                "n_slots": N_SLOTS, "block_size": BLOCK_SIZE,
                "max_len": MAX_LEN, "prefix_sharing": self.prefix_sharing}

    def fleet(self, obs) -> List[Replica]:
        return [
            Replica(i, self.model, self.params, n_slots=N_SLOTS,
                    max_len=MAX_LEN, block_size=BLOCK_SIZE,
                    prefix_sharing=self.prefix_sharing, obs=obs)
            for i in range(N_REPLICAS)
        ]


@dataclasses.dataclass
class RunReport:
    violations: List[dict]
    summary: dict
    ticks: int

    @property
    def ok(self) -> bool:
        return not self.violations

    def signature(self) -> Tuple[str, ...]:
        """Order-insensitive violation fingerprint — two runs of the
        same schedule must produce the same signature (the determinism
        check replays rely on)."""
        return tuple(sorted(v["oracle"] for v in self.violations))


def run_schedule(
    wl: Workload,
    sched: Schedule,
    *,
    reliable: bool = True,
    dedup: bool = True,
    retry_budget: int = 8,
    max_ticks: int = 20_000,
    leak_blocks: bool = False,
    trace_out: Optional[str] = None,
) -> RunReport:
    """One deterministic run of ``sched`` against the oracle set.
    ``trace_out`` dumps the run's virtual-clock trace (Perfetto JSON) —
    the campaign writes one per minimal repro so a violation ships with
    its full timeline. ``leak_blocks`` arms the engines' seeded cancel
    -path refcount bug (teeth for ``block_conservation``)."""
    obs = Observability()
    fleet = wl.fleet(obs)
    for rep in fleet:
        rep.engine._chaos_leak_blocks = leak_blocks
    fe = Frontend(
        fleet, SimplifiedDelayModel(lambda_y=2.0),
        cost_per_replica=sched.cost_per_replica,
        retry_budget=retry_budget,
        events=list(sched.events),
        transport_faults=TransportFaults(sched.directives, sched.partitions),
        reliable=reliable, dedup=dedup,
        max_ticks=max_ticks,
        obs=obs,
    )
    gids = [fe.submit(p, m, arrival=a) for p, m, a in wl.requests]
    violations: List[dict] = []
    try:
        results = fe.run()
    except RuntimeError as e:
        # Stall / stranded / transport give-up: a liveness violation.
        # Leaks and open spans in a wedged plane are consequences, not
        # separate findings — report the root cause alone so shrinking
        # targets it.
        if trace_out:
            obs.tracer.export(trace_out)
        return RunReport(
            [{"oracle": "liveness", "detail": str(e)}],
            {}, fe.ticks,
        )

    if fe.dropped:
        violations.append({
            "oracle": "zero_drop",
            "detail": f"dropped gids {sorted(fe.dropped)}",
        })
    for g in gids:
        fr = results.get(g)
        if fr is not None and fr.done and list(fr.tokens) != list(wl.refs[g]):
            violations.append({
                "oracle": "byte_identity",
                "detail": f"gid {g}: got {list(fr.tokens)[:8]}..., "
                          f"want {list(wl.refs[g])[:8]}...",
            })
    for rep in fleet:
        live = rep.engine.live_rids()
        if live:
            violations.append({
                "oracle": "no_leaks",
                "detail": f"replica {rep.id} has live requests {live} "
                          "after drain",
            })
        if rep.engine.pool.n_active != 0:
            violations.append({
                "oracle": "no_leaks",
                "detail": f"replica {rep.id} pool has "
                          f"{rep.engine.pool.n_active} active slots",
            })
        mgr = rep.engine.pool.manager
        if mgr is not None and mgr.n_used_blocks != 0:
            violations.append({
                "oracle": "no_leaks",
                "detail": f"replica {rep.id} arena leaks "
                          f"{mgr.n_used_blocks} blocks",
            })
        errs = [] if mgr is None else mgr.audit()
        if errs:
            violations.append({
                "oracle": "block_conservation",
                "detail": f"replica {rep.id}: " + "; ".join(errs[:3]),
            })
    if not (fe.router.inflight == 0).all():
        violations.append({
            "oracle": "no_leaks",
            "detail": f"router inflight {fe.router.inflight.tolist()}",
        })
    if fe.transport.busy():
        violations.append({
            "oracle": "no_leaks",
            "detail": "transport not drained at exit",
        })
    errs = validate_trace(obs.tracer.events)
    if errs:
        violations.append({
            "oracle": "trace", "detail": "; ".join(errs[:3]),
        })
    if obs.tracer.open_spans:
        violations.append({
            "oracle": "trace",
            "detail": f"open spans {obs.tracer.open_spans[:5]}",
        })
    terminal = {g: (results[g].done, results[g].dropped)
                for g in gids if g in results}
    if set(terminal) != set(gids):
        violations.append({
            "oracle": "conservation",
            "detail": f"missing results for {sorted(set(gids) - set(terminal))}",
        })
    for g, (done, dropped) in terminal.items():
        if done == dropped:     # both or neither
            violations.append({
                "oracle": "conservation",
                "detail": f"gid {g} terminal state done={done} "
                          f"dropped={dropped}",
            })
    summary = fe.summary()
    if summary["completed"] + summary["dropped"] != len(gids):
        violations.append({
            "oracle": "conservation",
            "detail": f"completed {summary['completed']} + dropped "
                      f"{summary['dropped']} != submitted {len(gids)}",
        })
    for port in fe.ports:
        seen: Dict[Tuple[int, int], int] = {}
        for key in port.admission_log:
            seen[key] = seen.get(key, 0) + 1
        dups = {k: c for k, c in seen.items() if c > 1}
        if dups:
            violations.append({
                "oracle": "exactly_once",
                "detail": f"replica {port.rep.id} admitted copies "
                          f"{sorted(dups)} more than once",
            })
    if trace_out:
        obs.tracer.export(trace_out)
    return RunReport(violations, summary, fe.ticks)


# ---------------------------------------------------------------------------
# Shrinking: greedy one-atom-at-a-time ddmin to a fixpoint
# ---------------------------------------------------------------------------

def shrink(
    wl: Workload, sched: Schedule, signature: Tuple[str, ...], **knobs
) -> Schedule:
    """Remove schedule atoms one at a time, keeping a removal whenever
    the SAME violation signature still reproduces, until no single
    removal preserves it (1-minimal in the ddmin sense). Deterministic
    runs make every probe exact — no flaky shrinks."""
    cur = sched
    changed = True
    while changed:
        changed = False
        for atom in cur.atoms():
            cand = cur.without([atom])
            if run_schedule(wl, cand, **knobs).signature() == signature:
                cur = cand
                changed = True
                break
    return cur


# ---------------------------------------------------------------------------
# Campaign driver + repro files
# ---------------------------------------------------------------------------

def write_repro(
    path: str, *, seed: int, index: int, wl: Workload, sched: Schedule,
    report: RunReport, knobs: dict,
) -> dict:
    payload = {
        "schema": REPRO_SCHEMA,
        "seed": seed,
        "index": index,
        "knobs": knobs,
        "workload": wl.as_dict(),
        "schedule": sched.as_dict(),
        "violations": report.violations,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return payload


def replay_repro(path: str) -> RunReport:
    with open(path) as f:
        payload = json.load(f)
    w = payload["workload"]
    wl = Workload(arch=w["arch"], n_requests=w["n_requests"], seed=w["seed"],
                  prefix_sharing=w.get("prefix_sharing", False))
    sched = Schedule.from_dict(payload["schedule"])
    return run_schedule(wl, sched, **payload["knobs"])


def run_campaign(
    *, schedules: int, seed: int, fast: bool, reliable: bool, dedup: bool,
    repro_dir: str, out: Optional[str], expect_violations: bool,
    leak_blocks: bool = False, prefix_sharing: bool = False,
) -> int:
    wl = Workload(n_requests=4 if fast else 6, prefix_sharing=prefix_sharing)
    knobs = {
        "reliable": reliable, "dedup": dedup,
        "retry_budget": 8, "max_ticks": 6_000 if fast else 20_000,
        "leak_blocks": leak_blocks,
    }
    t0 = time.perf_counter()
    n_bad, repros, op_counts = 0, [], {}
    for i in range(schedules):
        rng = np.random.default_rng([seed, i])
        sched = sample_schedule(rng)
        for ev in sched.events:
            op_counts[ev.kind] = op_counts.get(ev.kind, 0) + 1
        for d in sched.directives:
            op_counts[d.op] = op_counts.get(d.op, 0) + 1
        op_counts["partition"] = op_counts.get("partition", 0) + len(
            sched.partitions
        )
        report = run_schedule(wl, sched, **knobs)
        if report.ok:
            continue
        n_bad += 1
        sig = report.signature()
        small = shrink(wl, sched, sig, **knobs)
        os.makedirs(repro_dir, exist_ok=True)
        path = os.path.join(repro_dir, f"repro_s{seed}_i{i}.json")
        trace = os.path.join(repro_dir, f"trace_s{seed}_i{i}.json")
        confirm = run_schedule(wl, small, trace_out=trace, **knobs)
        replay = run_schedule(wl, small, **knobs)
        deterministic = confirm.signature() == replay.signature() == sig
        write_repro(path, seed=seed, index=i, wl=wl, sched=small,
                    report=confirm, knobs=knobs)
        repros.append({
            "index": i, "file": path, "signature": list(sig),
            "atoms": small.size(), "deterministic": deterministic,
        })
        print(f"[chaos-search] schedule {i}: VIOLATION {sig} "
              f"shrunk {sched.size()} -> {small.size()} atoms "
              f"(deterministic={deterministic}) -> {path}")
    wall = time.perf_counter() - t0
    print(f"[chaos-search] {schedules} schedules, {n_bad} violations, "
          f"{wall:.1f}s wall")
    if out:
        from benchmarks.common import write_bench_json
        write_bench_json(out, {
            "benchmark": "chaos_search",
            "mode": "fast" if fast else "full",
            "schedules": schedules,
            "seed": seed,
            "reliable": reliable,
            "dedup": dedup,
            "violations": n_bad,
            "wall_seconds": round(wall, 3),
            "fault_mix": op_counts,
            "repros": repros,
        })
        print(f"[chaos-search] summary -> {out}")
    if expect_violations:
        ok = n_bad > 0 and all(r["deterministic"] for r in repros)
        if not ok:
            print("[chaos-search] expected violations but the campaign "
                  "passed (or a repro replayed non-deterministically)")
        return 0 if ok else 1
    return 0 if n_bad == 0 else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--schedules", type=int, default=500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="smaller workload + tighter stall cap (CI)")
    ap.add_argument("--no-reliable", action="store_true",
                    help="disable ack/retransmit (violation demo)")
    ap.add_argument("--no-dedup", action="store_true",
                    help="disable receiver dedup (violation demo)")
    ap.add_argument("--leak-blocks", action="store_true",
                    help="seed a cancel-path refcount bug (conservation "
                         "oracle violation demo)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="run the fleet with copy-on-write prefix sharing")
    ap.add_argument("--expect-violations", action="store_true",
                    help="exit 0 iff the campaign FINDS (and "
                         "deterministically shrinks) a violation")
    ap.add_argument("--repro-dir", default="chaos_repros")
    ap.add_argument("--out", default=None,
                    help="write campaign summary BENCH json here")
    ap.add_argument("--replay", default=None,
                    help="replay one minimal-repro JSON and report")
    args = ap.parse_args(argv)

    if args.replay:
        report = replay_repro(args.replay)
        print(json.dumps({
            "violations": report.violations,
            "ticks": report.ticks,
        }, indent=2))
        return 0 if report.violations else 1

    return run_campaign(
        schedules=args.schedules, seed=args.seed, fast=args.fast,
        reliable=not args.no_reliable, dedup=not args.no_dedup,
        repro_dir=args.repro_dir, out=args.out,
        expect_violations=args.expect_violations,
        leak_blocks=args.leak_blocks, prefix_sharing=args.prefix_sharing,
    )


if __name__ == "__main__":
    sys.exit(main())
