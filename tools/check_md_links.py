#!/usr/bin/env python
"""Offline markdown link check for the repo docs (CI `docs` job).

Walks README.md, DESIGN.md, EXPERIMENTS.md, ROADMAP.md, PAPERS.md,
CHANGES.md and docs/*.md, extracts inline links `[text](target)`, and
verifies every non-http target resolves:

  * relative file targets must exist on disk (relative to the file);
  * `path#anchor` / `#anchor` targets must match a heading in the
    target markdown file (GitHub-style slugs: lowercase, punctuation
    stripped, spaces -> hyphens).

External http(s) links are listed but not fetched (CI has no business
depending on third-party uptime). Exits non-zero with a report of every
broken link.

    python tools/check_md_links.py [files...]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)

DEFAULT_FILES = [
    "README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md",
    "PAPERS.md", "CHANGES.md",
]


def github_slug(heading: str) -> str:
    """GitHub's anchor slug, close enough for our headings: strip
    markdown emphasis/code ticks, lowercase, drop everything but
    alphanumerics/spaces/hyphens, spaces -> hyphens."""
    h = re.sub(r"[`*_]", "", heading.strip())
    h = h.lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def anchors_of(path: Path) -> set:
    text = path.read_text(encoding="utf-8")
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(path: Path, root: Path) -> list:
    broken = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        line = text[: m.start()].count("\n") + 1
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        dest = path if not target else (path.parent / target).resolve()
        if not dest.exists():
            broken.append((path, line, m.group(1), "missing file"))
            continue
        if frag is not None and dest.suffix == ".md":
            if github_slug(frag) not in anchors_of(dest):
                broken.append((path, line, m.group(1), "missing anchor"))
    return broken


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    files = [Path(a) for a in sys.argv[1:]]
    if not files:
        files = [root / f for f in DEFAULT_FILES]
        files += sorted((root / "docs").glob("*.md"))
    files = [f for f in files if f.exists()]
    broken, checked = [], 0
    for f in files:
        checked += 1
        broken += check_file(f, root)
    if broken:
        print(f"BROKEN LINKS ({len(broken)}):")
        for path, line, target, why in broken:
            print(f"  {path.relative_to(root)}:{line}: ({target}) — {why}")
        return 1
    print(f"ok: {checked} files, no broken internal links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
